"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in Pallas **interpret mode**
— the kernel body runs in Python with the exact same blocking/masking
logic the TPU lowering uses.  On TPU they compile through Mosaic.  The
choice is automatic from the default backend, overridable per call.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.kv_gather import kv_layer_gather as _gather
from repro.kernels.kv_gather import kv_layer_scatter as _scatter
from repro.kernels.paged_attention import paged_attention as _paged


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, softcap=0.0, window=0,
                    block_q=None, block_k=None, interpret=None):
    kw = {}
    if block_q is not None:
        kw["block_q"] = block_q
    if block_k is not None:
        kw["block_k"] = block_k
    return _flash(q, k, v, causal=causal, softcap=softcap, window=window,
                  interpret=_interpret_default() if interpret is None
                  else interpret, **kw)


def paged_attention(q, k_pool, v_pool, block_table, lengths, *,
                    softcap=0.0, interpret=None):
    return _paged(q, k_pool, v_pool, block_table, lengths, softcap=softcap,
                  interpret=_interpret_default() if interpret is None
                  else interpret)


def kv_layer_gather(pool, table, *, layer: int, interpret=None):
    return _gather(pool, table, layer=layer,
                   interpret=_interpret_default() if interpret is None
                   else interpret)


def kv_layer_scatter(pool, table, stream, *, layer: int, interpret=None):
    return _scatter(pool, table, stream, layer=layer,
                    interpret=_interpret_default() if interpret is None
                    else interpret)


# re-export oracles for convenience in tests/benchmarks
flash_attention_ref = ref.flash_attention_ref
paged_attention_ref = ref.paged_attention_ref
kv_layer_gather_ref = ref.kv_layer_gather_ref
kv_layer_scatter_ref = ref.kv_layer_scatter_ref
