"""KV LayerBlock gather — the layerwise-prefill data-movement hotspot.

Layerwise prefill (paper §4.1) streams *one layer's* KV for the whole
prefix into HBM right before that layer's attention.  The prefix lives
in paged FullBlocks ``[layers, page_tokens, kv_feature]``; for layer l
the engine must gather ``pool[table[i], l]`` for every page i of the
sequence into a contiguous ``(n_pages·page_tokens, kv_feature)`` stream
buffer.  A gather like this is exactly the op that fragments into "a
multitude of fine-grained data chunks" (§4.3) — fusing it into one
Pallas kernel with scalar-prefetched page ids turns it into a single
pipelined DMA sweep (block i+1's HBM read overlaps block i's VMEM
write-out), the TPU analogue of the paper's doorbell-batched RDMA.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import tpu_params


def _gather_kernel(table_ref, pool_ref, out_ref):
    out_ref[0] = pool_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("layer", "interpret"))
def kv_layer_gather(pool, table, *, layer: int, interpret: bool = False):
    """pool (n_pool, layers, pt, feat); table (n,) i32 ->
    gathered (n, pt, feat) LayerBlock stream for ``layer``."""
    n_pool, n_layers, pt, feat = pool.shape
    n = table.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 1, pt, feat),
                         lambda i, tbl: (tbl[i], layer, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, pt, feat), lambda i, tbl: (i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, pt, feat), pool.dtype),
        compiler_params=tpu_params("arbitrary"),
        interpret=interpret,
    )(table, pool)


def _scatter_kernel(table_ref, stream_ref, pool_in_ref, out_ref):
    del pool_in_ref   # aliased with the output; only written pages change
    out_ref[0, 0] = stream_ref[0]


@functools.partial(jax.jit, static_argnames=("layer", "interpret"),
                   donate_argnums=(0,))
def kv_layer_scatter(pool, table, stream, *, layer: int,
                     interpret: bool = False):
    """Inverse of kv_layer_gather: write LayerBlocks back into FullBlock
    pages (used when persisting the newly-computed append KV).  The pool
    is donated and aliased with the output, so untouched pages persist
    without a copy."""
    n_pool, n_layers, pt, feat = pool.shape
    n = table.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, pt, feat), lambda i, tbl: (i, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, pt, feat),
                               lambda i, tbl: (tbl[i], layer, 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        compiler_params=tpu_params("arbitrary"),
        interpret=interpret,
        input_output_aliases={2: 0},
    )(table, stream, pool)
