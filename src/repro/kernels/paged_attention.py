"""Paged decode attention.

Decode engines keep KV in paged blocks (the same FullBlock token
granularity the storage layer uses), addressed by a per-sequence block
table.  One new token per sequence attends over its pages:

    q:           (batch, kv_heads, group, head_dim)
    k/v_pool:    (n_pages, page_tokens, kv_heads, head_dim)
    block_table: (batch, max_pages) int32     — page ids per sequence
    lengths:     (batch,) int32               — valid tokens per sequence

TPU mapping: grid (batch, kv_heads, n_pages) with the page dimension
innermost carrying online-softmax state; the block table and lengths
ride in scalar-prefetch so each page's BlockSpec index_map can pick the
right pool row (``table[b, i]``) while the DMA for page i+1 overlaps the
compute on page i — the HBM→VMEM streaming analogue of the paper's
layerwise loading.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import NEG_INF, tpu_params


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, page_tokens: int,
                  n_pages: int, softcap: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                       # (g, dh)
    k = k_ref[0, :, 0]                    # (page_tokens, dh)
    v = v_ref[0, :, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (g, pt)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = pi * page_tokens + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _fin():
        lse = l_ref[...]
        lse = jnp.where(lse == 0.0, 1.0, lse)
        o_ref[0, 0] = (acc_ref[...] / lse[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "interpret"))
def paged_attention(q, k_pool, v_pool, block_table, lengths, *,
                    softcap: float = 0.0, interpret: bool = False):
    """q (b, hkv, g, dh); pools (n_pages, pt, hkv, dh);
    block_table (b, max_pages) i32; lengths (b,) i32 -> (b, hkv, g, dh)."""
    b, hkv, g, dh = q.shape
    n_pool, pt, _, _ = k_pool.shape
    max_pages = block_table.shape[1]
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _paged_kernel, scale=scale, page_tokens=pt, n_pages=max_pages,
        softcap=softcap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b_, h, pi, tbl, ln: (b_, h, 0, 0)),
            pl.BlockSpec((1, pt, 1, dh),
                         lambda b_, h, pi, tbl, ln: (tbl[b_, pi], 0, h, 0)),
            pl.BlockSpec((1, pt, 1, dh),
                         lambda b_, h, pi, tbl, ln: (tbl[b_, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b_, h, pi, tbl, ln: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        compiler_params=tpu_params("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(block_table, lengths, q, k_pool, v_pool)
    return out
