import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each runnable cell this lowers the appropriate step —
``train_step`` (train_4k), ``prefill_step`` (prefill_32k) or
``serve_step`` (decode_32k / long_500k) — onto the production mesh
(16x16 single-pod, 2x16x16 multi-pod), compiles it, and extracts:

  * memory_analysis()   — proves the cell fits per-device HBM,
  * cost_analysis()     — HLO FLOPs / bytes for §Roofline,
  * collective bytes    — parsed from the compiled HLO (loop-aware).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, SHAPE_ORDER, get_config, shape_supported
from repro.configs.base import ARCH_IDS, ModelConfig
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import abstract_params, decode_step, forward, init_decode_state
from repro.models.sharding import param_partition_specs, use_mesh
from repro.roofline.hlo import parse_hlo_metrics, xla_cost_analysis
from repro.training.train import make_train_step

MOE_IMPL = "ep"


def _sds(shape, dtype, mesh, spec):
    from repro.models.sharding import sanitize_spec
    spec = sanitize_spec(shape, spec, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_spec(mesh, *rest):
    return P(batch_axes(mesh), *rest)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def decode_state_specs(cfg: ModelConfig, mesh, batch: int, seq_axis="auto"):
    """PartitionSpec tree matching init_decode_state(cfg, batch, S).

    ``seq_axis``: 'auto' (default) shards KV heads over ``model`` when the
    head count divides the axis, else falls back to sharding the KV
    *sequence* dim (context-parallel cache with distributed softmax).
    §Perf iteration 0: without the fallback, every arch with
    kv_heads ∤ 16 leaves the model axis idle on its decode cache and the
    decode_32k cells exceed 16 GB/chip (see results/dryrun_baseline_v0).
    Pass None to disable (v0 behaviour) or 'model' to force seq sharding.
    """
    b_ax = batch_axes(mesh) if batch % (
        2 * 16 if "pod" in mesh.axis_names else 16) == 0 else None
    if b_ax is None and batch >= 16 and batch % 16 == 0:
        b_ax = ("data",)    # shard over data only

    model_size = mesh.shape["model"]
    if seq_axis == "auto":
        heads_fit = cfg.n_kv_heads and cfg.n_kv_heads % model_size == 0
        seq_axis = None if heads_fit else "model"
        if cfg.attn_variant == "mla":
            seq_axis = "model"      # latent has no head dim to shard

    def kv(n_stack):
        lead = (None,) * len(n_stack)
        head_ax = "model" if seq_axis != "model" else None
        return {"k": P(*lead, b_ax, seq_axis, head_ax, None),
                "v": P(*lead, b_ax, seq_axis, head_ax, None)}

    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"kv": kv((0,))}
    if fam == "moe":
        m = cfg.moe
        out = {}
        if cfg.attn_variant == "mla":
            def mk(ns):
                lead = (None,) * len(ns)
                return {"c": P(*lead, b_ax, seq_axis, None),
                        "krope": P(*lead, b_ax, seq_axis, None)}
            if m.first_k_dense:
                out["dense"] = mk((0,))
            out["moe"] = mk((0,))
            if m.period > 1:
                out["pre"] = mk((0, 0))
            return out
        if m.first_k_dense:
            out["dense"] = kv((0,))
        out["moe"] = kv((0,))
        if m.period > 1:
            out["pre"] = kv((0, 0))
        return out
    if fam == "ssm":
        return {"mamba": {
            "ssm": P(None, b_ax, "model", None, None),
            "conv_x": P(None, b_ax, None, "model"),
            "conv_B": P(None, b_ax, None, None),
            "conv_C": P(None, b_ax, None, None),
        }}
    if fam == "hybrid":
        return {
            "mamba": {
                "ssm": P(None, None, b_ax, "model", None, None),
                "conv_x": P(None, None, b_ax, None, "model"),
                "conv_B": P(None, None, b_ax, None, None),
                "conv_C": P(None, None, b_ax, None, None),
            },
            # batch=1 long-context: shard the KV sequence over data
            # (context-parallel cache) when batch cannot shard
            "shared": {"k": P(None, b_ax, "data" if b_ax is None else None,
                              "model", None),
                       "v": P(None, b_ax, "data" if b_ax is None else None,
                              "model", None)},
        }
    raise ValueError(fam)


def input_specs(arch: str, shape_name: str, mesh, state_seq_axis=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend_embed_dim:
            return {"batch": {
                "inputs": _sds((gb, s, cfg.frontend_embed_dim), jnp.bfloat16,
                               mesh, _batch_spec(mesh, None, None)),
                "labels": _sds((gb, s), jnp.int32, mesh,
                               _batch_spec(mesh, None)),
            }}
        return {"batch": {"tokens": _sds((gb, s), jnp.int32, mesh,
                                         _batch_spec(mesh, None))}}
    if shape.kind == "prefill":
        if cfg.frontend_embed_dim:
            return {"inputs": _sds((gb, s, cfg.frontend_embed_dim),
                                   jnp.bfloat16, mesh,
                                   _batch_spec(mesh, None, None))}
        return {"inputs": _sds((gb, s), jnp.int32, mesh,
                               _batch_spec(mesh, None))}
    # decode
    state = init_decode_state(cfg, gb, s, abstract=True)
    sspecs = decode_state_specs(cfg, mesh, gb,
                                seq_axis=state_seq_axis or "auto")
    b_ax = None if gb < 16 else batch_axes(mesh)
    state_sds = jax.tree.map(
        lambda sds, spec: _sds(sds.shape, sds.dtype, mesh, spec),
        state, sspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return {
        "tokens": _sds((gb,), jnp.int32, mesh, P(b_ax)),
        "state": state_sds,
        "lengths": _sds((gb,), jnp.int32, mesh, P(b_ax)),
    }


def param_specs(cfg: ModelConfig, mesh, overrides=None, profile=None):
    import dataclasses
    if profile:
        cfg = dataclasses.replace(cfg, sharding_profile=profile)
    pspecs = param_partition_specs(cfg, mesh, overrides)
    return jax.tree.map(
        lambda sds, spec: _sds(sds.shape, sds.dtype, mesh, spec),
        abstract_params(cfg), pspecs)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def opt_state_specs(cfg: ModelConfig, mesh, params_sds, overrides=None):
    from repro.training.optimizer import make_optimizer
    opt_init, _ = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    opt_abs = jax.eval_shape(opt_init, params_sds)
    pspecs = param_partition_specs(cfg, mesh, overrides)
    if cfg.optimizer == "adamw":
        specs = {"m": pspecs, "v": pspecs, "step": P()}
    else:  # adafactor: factored state is small — replicate
        specs = jax.tree.map(lambda _: P(), opt_abs["fac"])
        specs = {"fac": specs, "step": P()}
    return jax.tree.map(
        lambda sds, spec: _sds(sds.shape, sds.dtype, mesh, spec),
        opt_abs, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_cell(arch: str, shape_name: str, mesh, variant=None):
    """Returns (jitted_fn, arg_sds_tuple).

    ``variant`` (hillclimbing knobs, all optional):
      weight_overrides  — logical-axis -> mesh-axis rule overrides
      profile           — replace the arch's sharding profile entirely
      act_overrides     — activation logical-axis rule overrides
      microbatches      — grad-accum depth for train cells
      remat             — False | 'full' | 'dots' | 'dots_no_batch'
      moe_impl          — 'ep' | 'ragged'
      capacity_factor   — MoE EP capacity factor
      state_seq_axis    — mesh axis to shard decode KV seq dim over
      cache_mode        — decode cache: 'scan_xs' | 'carry' (in-place)
    """
    v = variant or {}
    overrides = v.get("weight_overrides")
    moe_impl = v.get("moe_impl", MOE_IMPL)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p_sds = param_specs(cfg, mesh, overrides, profile=v.get("profile"))
    ins = input_specs(arch, shape_name, mesh,
                      state_seq_axis=v.get("state_seq_axis"))

    if shape.kind == "train":
        _, train_step = make_train_step(
            cfg, moe_impl=moe_impl,
            n_microbatches=v.get("microbatches"),
            remat=v.get("remat", "full"))
        import dataclasses
        ocfg = dataclasses.replace(cfg, sharding_profile=v["profile"]) \
            if v.get("profile") else cfg
        o_sds = opt_state_specs(ocfg, mesh, p_sds, overrides)
        fn = jax.jit(train_step, donate_argnums=(0, 1))
        return fn, (p_sds, o_sds, ins["batch"])

    if shape.kind == "prefill":
        ret_state = cfg.supports_decode
        pmb = v.get("prefill_microbatch")

        def _fwd(params, inputs):
            return forward(params, cfg, inputs, return_state=ret_state,
                           moe_impl=moe_impl, last_only=True,
                           capacity_factor=v.get("capacity_factor", 1.25))

        if pmb:
            from repro.engines.kvio import batch_axes_of_state

            def prefill_step(params, inputs):
                gb = inputs.shape[0]
                micro = inputs.reshape((pmb, gb // pmb) + inputs.shape[1:])
                outs = jax.lax.map(lambda inp: _fwd(params, inp), micro)
                logits, state = outs
                logits = logits.reshape((gb,) + logits.shape[2:])
                if not ret_state:
                    return logits
                axes = batch_axes_of_state(cfg)
                state = jax.tree.map(
                    lambda a, ax: jnp.moveaxis(a, 0, ax).reshape(
                        a.shape[1:ax + 1] + (gb,) + a.shape[ax + 2:]),
                    state, axes)
                return logits, state
        else:
            def prefill_step(params, inputs):
                out = _fwd(params, inputs)
                return out if ret_state else out[0]

        fn = jax.jit(prefill_step)
        return fn, (p_sds, ins["inputs"])

    def serve_step(params, tokens, state, lengths):
        return decode_step(params, cfg, tokens, state, lengths,
                           moe_impl=moe_impl,
                           capacity_factor=v.get("capacity_factor", 1.25),
                           cache_mode=v.get("cache_mode", "scan_xs"))

    fn = jax.jit(serve_step, donate_argnums=(2,))
    return fn, (p_sds, ins["tokens"], ins["state"], ins["lengths"])


# ---------------------------------------------------------------------------
# run
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant=None, verbose: bool = True,
             hlo_dir: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape_name,
                    mesh="multi" if multi_pod else "single",
                    status="skipped", reason=why)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    v = variant or {}
    with use_mesh(mesh, v.get("profile", cfg.sharding_profile),
                  act_overrides=v.get("act_overrides")):
        fn, args = build_cell(arch, shape_name, mesh, variant)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)   # list-vs-dict across JAX versions
    hlo = compiled.as_text()
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    # loop-aware per-device metrics (XLA's cost_analysis counts while
    # bodies once — see repro.roofline.hlo); raw numbers kept for reference
    metrics = parse_hlo_metrics(hlo)
    out = dict(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single",
        status="ok",
        n_devices=mesh.size,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        flops=metrics.get("flops", 0.0),
        bytes_accessed=metrics.get("bytes", 0.0),
        collective_bytes=metrics.get("collective_bytes", 0.0),
        collectives={k: v for k, v in metrics.items()
                     if k in ("all-gather", "all-reduce", "reduce-scatter",
                              "all-to-all", "collective-permute") and v},
        xla_cost_flops=cost.get("flops", 0.0) if cost else 0.0,
        xla_cost_bytes=cost.get("bytes accessed", 0.0) if cost else 0.0,
    )
    if mem is not None:
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[k] = v
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}: OK "
              f"(lower {out['lower_s']}s, compile {out['compile_s']}s, "
              f"GFLOPs {out['flops']/1e9:.1f}, "
              f"coll {out['collective_bytes']/1e9:.3f} GB)")
        print(f"  memory_analysis: "
              f"{ {k: v for k, v in out.items() if k.endswith('bytes')} }")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-dir", default=None,
                    help="save gzipped compiled HLO per cell (re-analysis)")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = SHAPE_ORDER if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    done = {}
    if args.out and os.path.exists(args.out):
        try:
            for r in json.load(open(args.out)):
                done[(r["arch"], r["shape"], r["mesh"])] = r
        except Exception:
            done = {}

    def flush():
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out + ".tmp", "w") as f:
                json.dump(results, f, indent=1)
            os.replace(args.out + ".tmp", args.out)

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = (arch, shape_name, "multi" if mp else "single")
                if key in done and done[key]["status"] in ("ok", "skipped"):
                    results.append(done[key])
                    continue
                try:
                    results.append(run_cell(arch, shape_name, mp,
                                            hlo_dir=args.hlo_dir))
                except Exception as e:  # noqa: BLE001 — report, don't die
                    results.append(dict(arch=arch, shape=shape_name,
                                        mesh="multi" if mp else "single",
                                        status="error", error=repr(e)[:500]))
                    print(f"[dryrun] {arch} × {shape_name} ERROR: {e}",
                          file=sys.stderr)
                flush()
    flush()
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
