import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Perf hillclimbing driver: re-lower a dry-run cell under a variant and
report the roofline-term deltas vs baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch qwen1.5-0.5b --shape train_4k \
        --variant '{"weight_overrides": {"mlp": null, "heads": null}}'

Variants are JSON dicts (see launch/dryrun.py::build_cell).  Results are
appended to results/hillclimb.json with the variant recorded, so the
EXPERIMENTS.md §Perf log can cite exact configurations.
"""
import argparse
import json
import sys

from repro.launch.dryrun import run_cell

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def terms(rec):
    return dict(
        compute_ms=rec["flops"] / PEAK_FLOPS * 1e3,
        memory_ms=rec["bytes_accessed"] / HBM_BW * 1e3,
        collective_ms=rec["collective_bytes"] / LINK_BW * 1e3,
        temp_gb=rec.get("temp_size_in_bytes", 0) / 1e9,
        arg_gb=rec.get("argument_size_in_bytes", 0) / 1e9,
    )


def fmt(t):
    return (f"compute={t['compute_ms']:.2f}ms memory={t['memory_ms']:.2f}ms "
            f"collective={t['collective_ms']:.2f}ms temp={t['temp_gb']:.2f}GB "
            f"args={t['arg_gb']:.2f}GB")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="{}")
    ap.add_argument("--label", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/hillclimb.json")
    ap.add_argument("--no-baseline", action="store_true")
    args = ap.parse_args(argv)

    variant = json.loads(args.variant)
    rows = []
    if not args.no_baseline:
        base = run_cell(args.arch, args.shape, args.multi_pod, verbose=False)
        base["variant"] = "baseline"
        rows.append(base)
        print(f"baseline : {fmt(terms(base))}")
    rec = run_cell(args.arch, args.shape, args.multi_pod, variant=variant,
                   verbose=False)
    rec["variant"] = args.label or json.dumps(variant, sort_keys=True)
    rows.append(rec)
    t = terms(rec)
    print(f"variant  : {fmt(t)}")
    if rows[0] is not rec and rows[0]["status"] == "ok":
        b = terms(rows[0])
        for k in ("compute_ms", "memory_ms", "collective_ms", "temp_gb"):
            if b[k] > 0:
                print(f"  Δ{k}: {100 * (t[k] / b[k] - 1):+.1f}%")
    prev = []
    if os.path.exists(args.out):
        prev = json.load(open(args.out))
    prev.extend(rows)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(prev, f, indent=1)


if __name__ == "__main__":
    sys.exit(main())
