"""Training launcher.

On real TPU this runs the sharded train step over the production mesh;
on CPU it runs reduced configs end-to-end (same code path minus mesh).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 50 --reduced
"""
from __future__ import annotations

import argparse

import jax

from repro.ckpt import FaultTolerantRunner
from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.models import init_params
from repro.models.sharding import param_shardings, use_mesh
from repro.training import SyntheticLM, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--mesh", choices=("none", "single", "multi"),
                    default="none")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    opt_init, train_step = make_train_step(cfg, lr=args.lr,
                                           n_microbatches=2)
    with use_mesh(mesh, cfg.sharding_profile):
        params = init_params(cfg, jax.random.PRNGKey(0))
        if mesh is not None:
            params = jax.device_put(params, param_shardings(cfg, mesh))
        ts = jax.jit(train_step, donate_argnums=(0, 1))
        pipe = SyntheticLM(cfg.vocab_size, args.batch, args.seq, seed=0)
        runner = FaultTolerantRunner(args.ckpt_dir, ts, params,
                                     opt_init(params), pipe, ckpt_every=25)
        if runner.try_resume():
            print(f"resumed at step {runner.step}")
        losses = runner.run(args.steps)
    print(f"steps {runner.step}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
