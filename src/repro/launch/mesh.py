"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Shapes: 16x16 = one v5e pod (256 chips);
(2,16,16) = two pods, 512 chips — the ``pod`` axis is pure data
parallelism (weights replicated per pod, gradients all-reduced across
pods), which is the elastic unit for 1000+-node deployments.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= need, (
        f"need {need} devices, have {len(devs)} — the dry-run entrypoint "
        "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
        "before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
