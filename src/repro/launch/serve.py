"""Serving launcher: run the DualPath serving system on an arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --agents 4 --mode dualpath
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import ServingSystem
from repro.sim.traces import Round, Trajectory


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--mode", choices=("dualpath", "basic"),
                    default="dualpath")
    ap.add_argument("--pe", type=int, default=1)
    ap.add_argument("--de", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    system = ServingSystem(cfg, params, n_pe=args.pe, n_de=args.de,
                           mode=args.mode, block_tokens=16, max_seq=256,
                           de_slots=max(4, args.agents))
    trajs = [Trajectory(i, [Round(20, 4)] * args.rounds)
             for i in range(args.agents)]
    sessions = system.run_offline(trajs)
    print(f"completed {sum(s.rounds_done for s in sessions)} rounds "
          f"across {len(sessions)} agents ({args.mode})")
    for k, v in system.stats().items():
        print(f"  {k}: {v:,}" if isinstance(v, int) else f"  {k}: {v}")


if __name__ == "__main__":
    main()
