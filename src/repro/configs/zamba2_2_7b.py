"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]
54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
One shared transformer block (attention + FFN, single weight copy) is
applied every 6 Mamba2 layers (9 applications); each application keeps
its own KV cache.  Zamba2's per-application LoRA adapters are omitted
(noted in DESIGN.md §5) — weight sharing is the architectural property
that matters for KV/cache behaviour.
Sub-quadratic backbone: runs the long_500k cell (attention at decode is
O(seq) per step; SSM state is O(1)).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    vocab_size=32000,
    attn_variant="gqa",
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=0,                     # backbone blocks are pure Mamba2
    hybrid_period=6,
    hybrid_d_ff=10240,
    ssm=SSMConfig(
        d_state=64,
        head_dim=64,
        expand=2,
        conv_width=4,
        n_groups=1,
        chunk_size=256,
    ),
    tie_embeddings=True,
    rope_theta=10_000.0,
    sharding_profile="tp",
    microbatches_train_4k=4,
    supports_decode=True,
    sub_quadratic=True,
    source="arXiv:2411.15242; hf",
))
