"""llama4-maverick-400b-a17b — MoE, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Maverick interleaves dense and MoE FFNs (period 2) and adds one shared
expert per MoE layer; with 128 routed experts of d_ff 8192 on 24 MoE
layers this lands at ~398 B total / ~17 B active parameters, matching
the 400b-a17b designation.

Training policy: Adafactor with bf16 accumulators + 16 microbatches so
the train_4k cell fits 16 GB/chip on the 16x16 mesh (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    vocab_size=202048,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    ffn_activation="silu_gated",
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        d_ff_expert=8192,
        n_shared_experts=1,
        period=2,
        first_k_dense=0,
    ),
    rope_theta=500_000.0,
    sharding_profile="ep_fsdp",
    optimizer="adafactor",
    opt_state_dtype="bfloat16",
    microbatches_train_4k=16,
    supports_decode=True,
    sub_quadratic=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
