"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).

[arXiv:2106.07447; unverified]
48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504 (cluster units).
Encoder-only: bidirectional attention, no decode step (decode shapes
skipped per assignment).  The CNN waveform frontend is a stub —
``input_specs()`` provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    vocab_size=504,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    ffn_activation="gelu",
    causal=False,
    frontend_embed_dim=1280,     # precomputed conv-frame embeddings
    rope_theta=10_000.0,
    sharding_profile="tp",
    microbatches_train_4k=4,
    supports_decode=False,
    sub_quadratic=False,
    source="arXiv:2106.07447; unverified",
))
