"""nemotron-4-15b — dense GQA with squared-ReLU (non-gated) FFN.

[arXiv:2402.16819; unverified]
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    vocab_size=256000,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    ffn_activation="squared_relu",
    rope_theta=10_000.0,
    sharding_profile="fsdp",
    microbatches_train_4k=8,
    supports_decode=True,
    sub_quadratic=False,
    source="arXiv:2402.16819; unverified",
))
