"""mamba2-1.3b — attention-free SSM (SSD, state-space duality).

[arXiv:2405.21060; unverified]
48L d_model=2048 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, 64 SSD heads of dim 64.
Sub-quadratic: runs the long_500k cell (constant-size recurrent state).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab_size=50280,
    attn_variant="none",
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # Mamba2 blocks replace the FFN entirely
    ssm=SSMConfig(
        d_state=128,
        head_dim=64,
        expand=2,
        conv_width=4,
        n_groups=1,
        chunk_size=256,
    ),
    tie_embeddings=True,
    sharding_profile="tp",
    microbatches_train_4k=4,
    supports_decode=True,
    sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
))
