"""llava-next-34b — VLM backbone (anyres tiling frontend is a stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The modality frontend supplies precomputed patch embeddings via
``input_specs()``; the backbone below is a standard GQA decoder.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    vocab_size=64000,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    ffn_activation="silu_gated",
    rope_theta=5_000_000.0,
    frontend_embed_dim=7168,      # anyres patch embeddings, precomputed
    sharding_profile="fsdp",
    microbatches_train_4k=8,
    supports_decode=True,
    sub_quadratic=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
