"""gemma2-2b — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]
26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Layers alternate sliding-window (4096) and global attention; attention
logits softcapped at 50, final logits at 30; extra post-attention norms.
NOTE (DESIGN.md §5): despite the local layers being sub-quadratic, the
alternating *global* layers are full attention, so gemma2-2b does not
qualify for the long_500k cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    vocab_size=256000,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    ffn_activation="gelu_gated",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    local_window=4096,
    local_global_period=2,
    post_attn_norm=True,
    embed_scale=2304 ** 0.5,
    tie_embeddings=True,
    rope_theta=10_000.0,
    sharding_profile="tp",
    microbatches_train_4k=4,
    supports_decode=True,
    sub_quadratic=False,
    source="arXiv:2408.00118; hf",
))
