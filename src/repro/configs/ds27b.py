"""ds27b — the paper's own evaluation model (§A.2, downscaled DeepSeek).

30L hidden=2560, dense intermediate 12288, 32 heads, MLA attention,
72 routed experts (d_ff 1536, top-6) + 2 shared experts, 1 initial
dense layer.  The DeepSeek Sparse Attention indexer is orthogonal to
DualPath's loading path (it reduces *compute*, not KV residency) and is
not reproduced; MLA is, since it determines the per-token KV bytes that
drive the paper's Table 1 cache-compute ratios.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="ds27b",
    family="moe",
    n_layers=30,
    d_model=2560,
    vocab_size=129280,
    attn_variant="mla",
    n_heads=32,
    n_kv_heads=32,             # MLA: all heads share the latent KV
    head_dim=192,              # nope(128) + rope(64)
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    d_ff=12288,
    ffn_activation="silu_gated",
    moe=MoEConfig(
        n_experts=72,
        top_k=6,
        d_ff_expert=1536,
        n_shared_experts=2,
        period=1,
        first_k_dense=1,
    ),
    rope_theta=10_000.0,
    sharding_profile="tp",
    microbatches_train_4k=8,
    supports_decode=True,
    sub_quadratic=False,
    source="paper §A.2",
))
