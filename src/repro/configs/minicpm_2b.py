"""minicpm-2b — dense llama-like arch trained with a WSD schedule.

[arXiv:2404.06395; hf]
40L d_model=2304 36H (GQA kv=36 = MHA) d_ff=5760 vocab=122753.
MiniCPM's mup-style residual scaling is carried as ``ffn_mult``
(depth-scaled residual multiplier 1.4/sqrt(40)); the WSD learning-rate
schedule lives in repro.training.schedules.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    vocab_size=122753,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    ffn_activation="silu_gated",
    tie_embeddings=True,
    ffn_mult=1.4 / (40 ** 0.5),
    rope_theta=10_000.0,
    sharding_profile="tp",
    microbatches_train_4k=4,
    supports_decode=True,
    sub_quadratic=False,
    source="arXiv:2404.06395; hf",
))
