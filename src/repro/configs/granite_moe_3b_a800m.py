"""granite-moe-3b-a800m — fine-grained MoE, top-8 of 40 experts.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    vocab_size=49155,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    ffn_activation="silu_gated",
    moe=MoEConfig(
        n_experts=40,
        top_k=8,
        d_ff_expert=512,
        n_shared_experts=0,
        period=1,
        first_k_dense=0,
    ),
    tie_embeddings=True,
    rope_theta=10_000.0,
    sharding_profile="tp",
    microbatches_train_4k=4,
    supports_decode=True,
    sub_quadratic=False,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
