"""Model / shape configuration system.

Every assigned architecture is expressed as a ``ModelConfig`` — a flat,
frozen dataclass rich enough to cover dense GQA transformers, MoE, MLA,
Mamba2 (SSD), hybrid (Zamba2-style shared attention blocks) and
encoder-only models.  Configs are *data*: the model zoo in
``repro.models`` interprets them.

Shapes are the assigned (seq_len, global_batch, kind) cells.  Each config
declares which shape kinds it supports; ``cells_for(cfg)`` yields the
runnable (config, shape) cells and the documented skips.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    period: int = 1          # MoE every `period` layers (2 = alternate dense/MoE)
    first_k_dense: int = 0   # leading dense layers before any MoE layer
    router_logit_softcap: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD configuration."""

    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk_size: int = 256    # SSD chunk length for the chunked-scan algorithm


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-style) configuration."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0      # 0 = no Q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

_FAMILIES = ("dense", "moe", "ssm", "hybrid", "encoder", "vlm")
_ATTN_VARIANTS = ("gqa", "mla", "none")
_FFN_ACTS = ("silu_gated", "gelu_gated", "squared_relu", "gelu")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # one of _FAMILIES
    n_layers: int
    d_model: int
    vocab_size: int

    # --- attention ---
    attn_variant: str = "gqa"       # gqa | mla | none (ssm)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    # sliding-window / local-global alternation (gemma2): period 0 = all global.
    local_window: int = 0
    local_global_period: int = 0    # e.g. 2 -> layers alternate local, global
    rope_theta: float = 10000.0
    causal: bool = True             # False => encoder-only (bidirectional)

    # --- FFN ---
    d_ff: int = 0
    ffn_activation: str = "silu_gated"

    # --- optional subsystems ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None

    # --- hybrid (zamba2-style): a shared attention+FFN block applied every
    #     `hybrid_period` backbone layers, reusing one set of weights ---
    hybrid_period: int = 0
    hybrid_d_ff: int = 0

    # --- embeddings / head ---
    tie_embeddings: bool = False
    # Modality frontend stub: if set, inputs are precomputed frame/patch
    # embeddings of this dimension instead of token ids.
    frontend_embed_dim: int = 0

    # --- norm ---
    embed_scale: float = 1.0        # gemma2 multiplies embeddings by sqrt(d)
    rms_norm_eps: float = 1e-5
    post_attn_norm: bool = False    # gemma2-style extra norms
    ffn_mult: float = 1.0           # minicpm-style residual scaling (mup)

    # --- dtype / training policy ---
    param_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"
    optimizer: str = "adamw"        # adamw | adafactor
    opt_state_dtype: str = "float32"
    microbatches_train_4k: int = 8  # grad-accum steps for the train_4k shape

    # --- distribution profile (baseline; hillclimbing may override) ---
    sharding_profile: str = "tp"    # tp | fsdp | ep_fsdp

    # --- capability flags ---
    supports_decode: bool = True
    sub_quadratic: bool = False     # may run long_500k
    source: str = ""                # provenance tag from the assignment

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.family in _FAMILIES, self.family
        assert self.attn_variant in _ATTN_VARIANTS, self.attn_variant
        assert self.ffn_activation in _FFN_ACTS, self.ffn_activation
        if self.attn_variant == "gqa" and self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, (
                f"{self.name}: n_heads {self.n_heads} not a multiple of "
                f"n_kv_heads {self.n_kv_heads}")

    # --- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token KV/state bytes that must be *loaded* on a cache hit.

        This is the quantity driving the paper's cache-compute ratio
        (Table 1).  For SSM layers the recurrent state is O(1) per
        sequence, not per token, and contributes 0 here.
        """
        total = 0
        for kind in self.layer_kinds():
            if kind in ("attn", "local_attn"):
                if self.attn_variant == "mla":
                    assert self.mla is not None
                    total += (self.mla.kv_lora_rank + self.mla.rope_head_dim) * dtype_bytes
                else:
                    total += 2 * self.kv_dim * dtype_bytes
            # 'ssm' layers: constant-size state, no per-token growth.
        if self.hybrid_period:
            n_apps = self.n_layers // self.hybrid_period
            total += n_apps * 2 * self.kv_dim * dtype_bytes
        return total

    def ssm_state_bytes(self, dtype_bytes: int = 4) -> int:
        """Constant per-sequence recurrent state bytes (SSM/hybrid archs)."""
        if self.ssm is None:
            return 0
        d_inner = self.ssm.expand * self.d_model
        n_ssm_heads = d_inner // self.ssm.head_dim
        per_layer = (n_ssm_heads * self.ssm.head_dim * self.ssm.d_state
                     + (self.ssm.conv_width - 1) *
                     (d_inner + 2 * self.ssm.n_groups * self.ssm.d_state))
        n_ssm_layers = sum(1 for k in self.layer_kinds() if k == "ssm")
        return n_ssm_layers * per_layer * dtype_bytes

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind: 'attn' | 'local_attn' | 'ssm'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm", "hybrid"):
                # hybrid: the backbone is SSM; the shared attention block is
                # accounted separately (hybrid_period applications).
                kinds.append("ssm")
            elif self.local_global_period and (
                    i % self.local_global_period != self.local_global_period - 1):
                kinds.append("local_attn")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        m = []
        for i in range(self.n_layers):
            if i < self.moe.first_k_dense:
                m.append(False)
            else:
                m.append((i - self.moe.first_k_dense) % self.moe.period
                         == self.moe.period - 1)
        return tuple(m)

    def param_count(self) -> int:
        """Analytic parameter count (used by Table 1 / roofline MODEL_FLOPS)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_active_params_analytic
        return count_active_params_analytic(self)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            vocab_size=max(min(self.vocab_size, 512), 128),
        )
        if self.attn_variant != "none":
            kw.update(n_heads=4,
                      n_kv_heads=min(max(self.n_kv_heads * 4 //
                                         max(self.n_heads, 1), 1), 4),
                      head_dim=32)
        if self.d_ff:
            kw.update(d_ff=256)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                first_k_dense=min(self.moe.first_k_dense, 1))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32)
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=32, rope_head_dim=16,
                nope_head_dim=32, v_head_dim=32)
        if self.hybrid_period:
            kw.update(hybrid_period=2, hybrid_d_ff=256)
        if self.local_global_period:
            kw.update(local_window=64)
        if self.frontend_embed_dim:
            kw.update(frontend_embed_dim=128)
        kw.update(microbatches_train_4k=1)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def shape_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(supported, reason-if-not)."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def cells_for(cfg: ModelConfig):
    """Yield (shape, supported, reason) for every assigned shape."""
    for name in SHAPE_ORDER:
        s = SHAPES[name]
        ok, why = shape_supported(cfg, s)
        yield s, ok, why


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "llava-next-34b",
    "llama4-maverick-400b-a17b",
    "granite-moe-3b-a800m",
    "qwen1.5-0.5b",
    "minicpm-2b",
    "gemma2-2b",
    "nemotron-4-15b",
    "mamba2-1.3b",
    "hubert-xlarge",
    "zamba2-2.7b",
)

# the paper's own evaluation model (downscaled DeepSeek, §A.2)
EXTRA_ARCH_IDS = ("ds27b",)

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs():
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all():
    import importlib
    for arch in ARCH_IDS + EXTRA_ARCH_IDS:
        importlib.import_module("repro.configs." + arch.replace("-", "_").replace(".", "_"))
