from repro.configs.base import (
    ARCH_IDS,
    EXTRA_ARCH_IDS,
    SHAPES,
    SHAPE_ORDER,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeSpec,
    all_configs,
    cells_for,
    get_config,
    register,
    shape_supported,
)

__all__ = [
    "ARCH_IDS", "EXTRA_ARCH_IDS", "SHAPES", "SHAPE_ORDER",
    "MLAConfig", "ModelConfig", "MoEConfig", "SSMConfig", "ShapeSpec",
    "all_configs", "cells_for", "get_config", "register", "shape_supported",
]
