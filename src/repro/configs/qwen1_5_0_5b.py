"""qwen1.5-0.5b — dense, QKV bias, MHA (kv=16).

[hf:Qwen/Qwen1.5-0.5B; hf]
24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    vocab_size=151936,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    qkv_bias=True,
    d_ff=2816,
    ffn_activation="silu_gated",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    sharding_profile="tp",
    microbatches_train_4k=2,
    supports_decode=True,
    sub_quadratic=False,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
))
